//! §5.1(c) adaptive window selection — comparing announcement policies.
//!
//! The paper's prototype announces the earliest-starting window and
//! names slack-aware / fragmentation-aware strategies as open
//! alternatives. This bench runs all five implemented policies on the
//! same trace under two load regimes.

#[path = "common/mod.rs"]
mod common;

use jasda::config::WindowPolicy;
use jasda::jasda::JasdaScheduler;
use jasda::report::Table;
use jasda::sim::SimEngine;

fn main() {
    println!("Figure: window announcement policies (§3.1, §5.1(c))\n");
    for (label, cfg0) in [
        ("light load (~0.6x)", common::light_cfg(61, 60)),
        ("contended (~1.3x)", common::contended_cfg(61, 60)),
    ] {
        let jobs = common::workload(&cfg0);
        let mut table = Table::new(
            format!("window policies — {label}"),
            &["policy", "util", "mean_jct", "p95_jct", "jain", "starv", "frag", "subjobs"],
        );
        for policy in WindowPolicy::ALL {
            let mut cfg = cfg0.clone();
            cfg.jasda.window_policy = policy;
            let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
                .run(jobs.clone())
                .metrics;
            assert_eq!(m.unfinished, 0, "{policy:?} left jobs unfinished");
            table.push_row(vec![
                policy.name().into(),
                format!("{:.3}", m.utilization),
                common::fmt0(m.mean_jct()),
                common::fmt0(m.jct_percentile(0.95)),
                common::fmt(m.jain_fairness()),
                format!("{}", m.max_starvation()),
                format!("{:.3}", m.mean_fragmentation),
                common::fmt(m.mean_subjobs()),
            ]);
        }
        println!("{}", table.to_markdown());
    }
}
