//! Table 3 / §4.5 — the paper's worked example, regenerated exactly,
//! plus a microbenchmark of the clearing routine on the example pool.
//!
//! Paper: window w* = (s2, 20 GB, t_min = 40, Δt = 10); bids
//! v_A1 = [40,47) h=.75 f=.55, v_A2 = [47,50) h=.60 f=.70,
//! v_B1 = [40,50) h=.80 f=.60; λ = 0.6. Expected clearing:
//! Ŝ = {v_A1, v_A2}, total score 1.31 (v_B1 deferred).

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::types::Interval;
use jasda::util::bench::{header, run_case};

fn example_pool() -> ([&'static str; 3], Vec<WisItem>) {
    let names = ["v_A1", "v_A2", "v_B1"];
    let lambda = 0.6;
    let rows = [
        (Interval::new(40, 47), 0.75, 0.55),
        (Interval::new(47, 50), 0.60, 0.70),
        (Interval::new(40, 50), 0.80, 0.60),
    ];
    let items = rows
        .iter()
        .map(|&(iv, h, f)| WisItem { interval: iv, score: lambda * h + (1.0 - lambda) * f })
        .collect();
    (names, items)
}

fn main() {
    header("Table 3 — paper worked example (§4.5)");
    let (names, items) = example_pool();
    println!("{:<6} {:>5} {:>4} {:>7}", "bid", "start", "end", "Score");
    for (n, it) in names.iter().zip(&items) {
        println!(
            "{:<6} {:>5} {:>4} {:>7.2}",
            n, it.interval.start, it.interval.end, it.score
        );
    }

    let sol = select_best_compatible(&items);
    let chosen: Vec<&str> = sol.selected.iter().map(|&i| names[i]).collect();
    println!("\nselected: {{{}}} total = {:.2}", chosen.join(", "), sol.total_score);
    println!("paper   : {{v_A1, v_A2}} total = 1.31");
    assert_eq!(chosen, vec!["v_A1", "v_A2"], "must match the paper exactly");
    assert!((sol.total_score - 1.31).abs() < 1e-9, "must match the paper exactly");
    println!("REPRODUCED: exact match.");

    header("clearing microbenchmark on the example pool");
    run_case("select_best_compatible(3 bids)", 20, 2, || {
        select_best_compatible(std::hint::black_box(&items)).total_score
    });
}
