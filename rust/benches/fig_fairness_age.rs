//! §4.3 temporal fairness — the age-aware prioritization ablation.
//!
//! Sweeps the age weight β_age (0 disables the mechanism entirely — the
//! ablation) and the saturation scale, and reports starvation and
//! waiting-time tails. Paper claim: the age term "mitigates starvation in
//! practice" and promotes long-term stability without hard guarantees.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::JasdaScheduler;
use jasda::report::Table;
use jasda::sim::SimEngine;

fn main() {
    let cfg0 = common::contended_cfg(41, 80);
    let jobs = common::workload(&cfg0);
    println!("Figure: age-aware fairness ablation (§4.3), {} jobs\n", jobs.len());

    let mut table = Table::new(
        "β_age sweep",
        &["beta_age", "age_scale", "max_starv", "p95_wait", "jain", "mean_jct", "util"],
    );
    let mut starv = Vec::new();
    for &(beta_age, scale) in
        &[(0.0, 30_000u64), (0.1, 30_000), (0.2, 30_000), (0.3, 30_000), (0.2, 5_000), (0.2, 120_000)]
    {
        let mut cfg = cfg0.clone();
        // Keep Σβ ≤ 1 by scaling the other three weights into 1 − β_age.
        let rest = 1.0 - beta_age;
        let base = cfg.jasda.beta;
        let s = (base.util + base.headroom + base.frag).max(1e-9);
        cfg.jasda.beta.util = base.util / s * rest * 0.8;
        cfg.jasda.beta.headroom = base.headroom / s * rest * 0.8;
        cfg.jasda.beta.frag = base.frag / s * rest * 0.8;
        cfg.jasda.beta.age = beta_age;
        cfg.jasda.age_priority = beta_age > 0.0;
        cfg.jasda.age_scale = scale;

        let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs.clone())
            .metrics;
        assert_eq!(m.unfinished, 0);
        starv.push((beta_age, m.max_starvation()));
        table.push_row(vec![
            format!("{beta_age:.1}"),
            format!("{scale}"),
            format!("{}", m.max_starvation()),
            common::fmt0(m.p95_wait()),
            common::fmt(m.jain_fairness()),
            common::fmt0(m.mean_jct()),
            format!("{:.3}", m.utilization),
        ]);
    }
    println!("{}", table.to_markdown());

    let no_age = starv.iter().find(|(b, _)| *b == 0.0).unwrap().1;
    let with_age = starv.iter().filter(|(b, _)| *b > 0.0).map(|(_, s)| *s).min().unwrap();
    println!(
        "max starvation: ablation {} vs best-with-age {} ({:.1}x reduction)",
        no_age,
        with_age,
        no_age as f64 / with_age.max(1) as f64
    );
}
