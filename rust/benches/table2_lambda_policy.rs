//! Table 2 — illustrative effects of the policy parameter λ.
//!
//! Paper: λ = 0.7 "QoS-first" favors job-centric metrics (latency,
//! QoS adherence); λ = 0.5 balanced; λ = 0.3 "Utilization-first"
//! emphasizes utilization/fragmentation at the cost of latency.
//! We sweep a denser grid and report the measured trend.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::JasdaScheduler;
use jasda::report::Table;
use jasda::sim::SimEngine;

fn main() {
    // Average over several traces: per-seed deadline rates are noisy
    // (few deadline-carrying jobs per trace).
    const SEEDS: [u64; 4] = [22, 122, 222, 322];
    println!("Table 2: λ sweep over {} traces x 70 jobs", SEEDS.len());

    let mut table = Table::new(
        "Table 2 — λ policy effects (measured, mean over traces)",
        &["lambda", "policy", "util", "mean_jct", "p95_jct", "deadline_rate", "jain", "starv"],
    );
    let mut deadline_rates = Vec::new();
    let mut jcts: Vec<f64> = Vec::new();
    for &lambda in &[0.1, 0.3, 0.5, 0.7, 0.9] {
        let policy = if lambda >= 0.65 {
            "QoS-first"
        } else if lambda <= 0.35 {
            "Utilization-first"
        } else {
            "Balanced"
        };
        let (mut util, mut jct, mut p95, mut dl, mut jain, mut starv) =
            (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        for &seed in &SEEDS {
            let cfg = common::contended_cfg(seed, 70);
            let jobs = common::workload(&cfg);
            let mut jcfg = cfg.jasda.clone();
            jcfg.lambda = lambda;
            let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(jcfg)))
                .run(jobs)
                .metrics;
            assert_eq!(m.unfinished, 0);
            util += m.utilization;
            jct += m.mean_jct().unwrap_or(0.0);
            p95 += m.jct_percentile(0.95).unwrap_or(0.0);
            dl += m.deadline_met_rate().unwrap_or(0.0);
            jain += m.jain_fairness().unwrap_or(0.0);
            starv += m.max_starvation() as f64;
        }
        let n = SEEDS.len() as f64;
        deadline_rates.push(dl / n);
        jcts.push(jct / n);
        table.push_row(vec![
            format!("{lambda:.2}"),
            policy.into(),
            format!("{:.3}", util / n),
            format!("{:.0}", jct / n),
            format!("{:.0}", p95 / n),
            format!("{:.3}", dl / n),
            format!("{:.3}", jain / n),
            format!("{:.0}", starv / n),
        ]);
    }
    println!("\n{}", table.to_markdown());

    // Table 2's claim is that high λ "prioritizes job-centric metrics
    // such as latency … and QoS adherence". Latency: directly testable.
    let jct_low: f64 = jcts[..2].iter().sum::<f64>() / 2.0;
    let jct_high: f64 = jcts[3..].iter().sum::<f64>() / 2.0;
    println!(
        "mean JCT (latency): utilization-first {:.0} vs QoS-first {:.0} -> {}",
        jct_low,
        jct_high,
        if jct_high <= jct_low {
            "matches Table 2 (QoS-first improves latency)"
        } else {
            "DIVERGES from Table 2"
        }
    );
    // Deadline adherence: measured to *decrease* with λ in this system —
    // a real coupling the paper does not anticipate: the age-fairness
    // term (§4.3) lives on the system side of Eq. (4), so QoS-first
    // (high λ) down-weights aging, and under contention deadline jobs
    // lose more to starvation than they gain from their urgency scores.
    // See EXPERIMENTS.md T2 for the discussion.
    let dl_low = deadline_rates[..2].iter().sum::<f64>() / 2.0;
    let dl_high = deadline_rates[3..].iter().sum::<f64>() / 2.0;
    println!(
        "deadline adherence: utilization-first {dl_low:.3} vs QoS-first {dl_high:.3} \
         (age-term coupling; see EXPERIMENTS.md)"
    );
}
