//! §4.6 asymptotics — scheduling overhead vs arrival rate.
//!
//! Paper claim: expected overhead per unit time is
//! O(λ_arr · V_max · (t_gen + log(λ_arr · V_max))) — quasi-linear in the
//! arrival rate, independent of workload heterogeneity. We sweep the
//! arrival rate, keep everything else fixed, and report the measured
//! scheduler wall-time per simulated second plus the bid-volume series.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::JasdaScheduler;
use jasda::report::Table;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    println!("Figure: scheduler overhead vs arrival rate (paper §4.6)\n");
    let mut table = Table::new(
        "JASDA overhead scaling with λ_arr",
        &[
            "rate(jobs/s)",
            "variants",
            "variants/iter",
            "sched_ns/iter",
            "sched_ms/sim_s",
            "util",
            "unfinished",
        ],
    );
    let mut ns_per_sim_s = Vec::new();
    for &rate in &[0.05, 0.1, 0.2, 0.4, 0.8] {
        let mut cfg = common::contended_cfg(31, 60);
        cfg.workload.arrival_rate_per_sec = rate;
        let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
        let out = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs);
        let m = &out.metrics;
        let variants =
            out.scheduler_stats.get("variants_submitted").and_then(|v| v.as_u64()).unwrap_or(0);
        let per_sim_s = m.sched_wall_ns as f64 / (m.makespan as f64 / 1000.0) / 1e6;
        ns_per_sim_s.push((rate, per_sim_s));
        table.push_row(vec![
            format!("{rate:.2}"),
            format!("{variants}"),
            format!("{:.2}", variants as f64 / m.iterations.max(1) as f64),
            format!("{:.0}", m.sched_ns_per_iteration()),
            format!("{per_sim_s:.2}"),
            format!("{:.3}", m.utilization),
            format!("{}", m.unfinished),
        ]);
    }
    println!("{}", table.to_markdown());

    // Quasi-linearity: overhead per simulated second at 16x the rate
    // should stay within ~64x (16x linear + log factor + variance).
    let lo = ns_per_sim_s.first().unwrap().1.max(1e-6);
    let hi = ns_per_sim_s.last().unwrap().1;
    println!(
        "overhead growth {:.1}x for a 16x arrival-rate increase (quasi-linear ≤ ~64x)",
        hi / lo
    );

    // K-window announcement sweep (ISSUE 1): at a fixed contended rate,
    // clearing K windows per iteration raises commitments per decision
    // round; makespan must not regress relative to K=1.
    println!("\nFigure: decision-round throughput vs announce_k\n");
    let mut ktable = Table::new(
        "JASDA K-window sweep (burst arrivals)",
        &["announce_k", "commits/iter", "max_commits/iter", "makespan(s)", "util", "unfinished"],
    );
    let mut baseline_makespan = 0u64;
    let mut baseline_cpi = 0.0;
    for (label, k, per_slice) in
        [("1", 1usize, false), ("2", 2, false), ("4", 4, false), ("per-slice", 1, true)]
    {
        let mut cfg = common::contended_cfg(47, 60);
        cfg.workload.arrival_rate_per_sec = 1e6; // burst: worst-case contention
        cfg.engine.iteration_period = 500; // decision-round-limited regime
        cfg.jasda.announce_k = k;
        cfg.jasda.announce_per_slice = per_slice;
        let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
        let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs)
            .metrics;
        if label == "1" {
            baseline_makespan = m.makespan;
            baseline_cpi = m.commits_per_iteration();
        }
        ktable.push_row(vec![
            label.to_string(),
            format!("{:.3}", m.commits_per_iteration()),
            format!("{}", m.max_commits_per_iter),
            format!("{:.1}", m.makespan as f64 / 1000.0),
            format!("{:.3}", m.utilization),
            format!("{}", m.unfinished),
        ]);
        if label != "1" {
            println!(
                "  K={label}: commits/iter {:.3} vs baseline {:.3} ({}); makespan {} vs {} ({})",
                m.commits_per_iteration(),
                baseline_cpi,
                if m.commits_per_iteration() > baseline_cpi { "UP" } else { "no gain" },
                m.makespan,
                baseline_makespan,
                if m.makespan <= baseline_makespan { "ok" } else { "REGRESSED" },
            );
        }
    }
    println!("\n{}", ktable.to_markdown());

    // Clearing-policy sweep (ISSUE 8): exact global clearing vs the
    // greedy baseline per K at the contended burst point. Welfare is the
    // run's summed composite score of accepted variants
    // (`award_score_sum`); K = 1 must tie exactly (no cross-window
    // constraints to improve on).
    println!("\nFigure: cleared welfare, greedy vs exact clearing per K\n");
    let mut etable = Table::new(
        "JASDA clearing policy (burst arrivals, budget 50ms)",
        &[
            "announce_k",
            "welfare(greedy)",
            "welfare(exact)",
            "uplift%",
            "util(greedy)",
            "util(exact)",
            "exact_rounds",
            "improved",
            "nodes",
        ],
    );
    for (label, k, per_slice) in
        [("1", 1usize, false), ("2", 2, false), ("4", 4, false), ("per-slice", 1, true)]
    {
        let mut results: Vec<(f64, f64, u64, u64, u64)> = Vec::new();
        for clearing in [jasda::config::ClearingMode::Greedy, jasda::config::ClearingMode::Exact]
        {
            let mut cfg = common::contended_cfg(47, 60);
            cfg.workload.arrival_rate_per_sec = 1e6; // burst: worst-case contention
            cfg.engine.iteration_period = 500;
            cfg.jasda.announce_k = k;
            cfg.jasda.announce_per_slice = per_slice;
            cfg.jasda.clearing = clearing;
            cfg.jasda.clearing_budget_ms = 50;
            let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
            let out = SimEngine::new(
                cfg.clone(),
                Box::new(JasdaScheduler::new(cfg.jasda.clone())),
            )
            .run(jobs);
            let g64 = |key: &str| {
                out.scheduler_stats.get(key).and_then(|v| v.as_u64()).unwrap_or(0)
            };
            let welfare = out
                .scheduler_stats
                .get("award_score_sum")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0);
            results.push((
                welfare,
                out.metrics.utilization,
                g64("exact_rounds"),
                g64("exact_improved"),
                g64("exact_nodes"),
            ));
        }
        let (gw, gu, ..) = results[0];
        let (ew, eu, rounds, improved, nodes) = results[1];
        etable.push_row(vec![
            label.to_string(),
            format!("{gw:.3}"),
            format!("{ew:.3}"),
            format!("{:+.2}", (ew - gw) / gw.max(1e-9) * 100.0),
            format!("{gu:.3}"),
            format!("{eu:.3}"),
            format!("{rounds}"),
            format!("{improved}"),
            format!("{nodes}"),
        ]);
        // Per-*round* exact welfare dominates greedy by construction
        // (property-tested in tests/properties.rs); across a whole run
        // the trajectories diverge after the first improved round, so
        // only the K=1 identity is asserted here: a single window has
        // no cross-window constraints, the solver never runs, and the
        // two modes must be bit-identical end to end.
        if label == "1" {
            assert!(
                (ew - gw).abs() < 1e-9 && rounds == 0,
                "K=1 exact must be bit-identical to greedy (welfare {ew} vs {gw}, \
                 {rounds} exact rounds)"
            );
        }
    }
    println!("{}", etable.to_markdown());

    // Pipeline latency (ISSUE 2): serial vs parallel clearing at the
    // contended burst point, per-slice announcement on a 2-GPU cluster.
    // The parallel pipeline must cut iteration latency while making the
    // exact same decisions (makespan/commits identical).
    println!("\nFigure: iteration latency, serial vs parallel clearing pipeline\n");
    let mut ptable = Table::new(
        "JASDA clearing pipeline (burst, per-slice announcement, 2 GPUs)",
        &["mode", "sched_ns/iter", "max_iter_ns", "makespan(s)", "commits/iter", "unfinished"],
    );
    let mut outcomes: Vec<(u64, f64)> = Vec::new();
    for (mode, threads) in [("serial", 1usize), ("parallel", 0)] {
        let mut cfg = common::contended_cfg(47, 60);
        cfg.cluster.num_gpus = 2;
        cfg.workload.arrival_rate_per_sec = 1e6; // burst: worst-case contention
        cfg.engine.iteration_period = 500;
        cfg.jasda.announce_per_slice = true;
        cfg.jasda.parallel = threads;
        let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
        let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs)
            .metrics;
        outcomes.push((m.makespan, m.commits_per_iteration()));
        ptable.push_row(vec![
            mode.to_string(),
            format!("{:.0}", m.sched_ns_per_iteration()),
            format!("{}", m.max_sched_iter_ns),
            format!("{:.1}", m.makespan as f64 / 1000.0),
            format!("{:.3}", m.commits_per_iteration()),
            format!("{}", m.unfinished),
        ]);
    }
    println!("{}", ptable.to_markdown());
    println!(
        "decision parity: {}",
        if outcomes[0] == outcomes[1] {
            "serial == parallel (bit-identical outcomes)"
        } else {
            "DIVERGED — parallel clearing changed decisions!"
        }
    );
}
