//! §4.6 asymptotics — scheduling overhead vs arrival rate.
//!
//! Paper claim: expected overhead per unit time is
//! O(λ_arr · V_max · (t_gen + log(λ_arr · V_max))) — quasi-linear in the
//! arrival rate, independent of workload heterogeneity. We sweep the
//! arrival rate, keep everything else fixed, and report the measured
//! scheduler wall-time per simulated second plus the bid-volume series.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::JasdaScheduler;
use jasda::report::Table;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    println!("Figure: scheduler overhead vs arrival rate (paper §4.6)\n");
    let mut table = Table::new(
        "JASDA overhead scaling with λ_arr",
        &[
            "rate(jobs/s)",
            "variants",
            "variants/iter",
            "sched_ns/iter",
            "sched_ms/sim_s",
            "util",
            "unfinished",
        ],
    );
    let mut ns_per_sim_s = Vec::new();
    for &rate in &[0.05, 0.1, 0.2, 0.4, 0.8] {
        let mut cfg = common::contended_cfg(31, 60);
        cfg.workload.arrival_rate_per_sec = rate;
        let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
        let out = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs);
        let m = &out.metrics;
        let variants =
            out.scheduler_stats.get("variants_submitted").and_then(|v| v.as_u64()).unwrap_or(0);
        let per_sim_s = m.sched_wall_ns as f64 / (m.makespan as f64 / 1000.0) / 1e6;
        ns_per_sim_s.push((rate, per_sim_s));
        table.push_row(vec![
            format!("{rate:.2}"),
            format!("{variants}"),
            format!("{:.2}", variants as f64 / m.iterations.max(1) as f64),
            format!("{:.0}", m.sched_ns_per_iteration()),
            format!("{per_sim_s:.2}"),
            format!("{:.3}", m.utilization),
            format!("{}", m.unfinished),
        ]);
    }
    println!("{}", table.to_markdown());

    // Quasi-linearity: overhead per simulated second at 16x the rate
    // should stay within ~64x (16x linear + log factor + variance).
    let lo = ns_per_sim_s.first().unwrap().1.max(1e-6);
    let hi = ns_per_sim_s.last().unwrap().1;
    println!(
        "overhead growth {:.1}x for a 16x arrival-rate increase (quasi-linear ≤ ~64x)",
        hi / lo
    );
}
