//! Table 1 — conceptual comparison of scheduling models, measured.
//!
//! The paper's Table 1 contrasts scheduling-model *classes*; this bench
//! quantifies those rows on a shared trace:
//!
//! * "Static/reactive, passive jobs"  → fcfs / sjf / edf / backfill
//!   (monolithic, scheduler-driven);
//! * "Cluster-level fairness"          → themis_like;
//! * "Atomized but centralized (SJA)"  → sja_central;
//! * "Cyclic bidirectional negotiation (JASDA)" → jasda.
//!
//! Measured columns map to Table 1's qualitative claims: per-window
//! granularity shows up as subjobs/job; active job participation as
//! bid statistics; continuous adaptation as starvation/fairness.

#[path = "common/mod.rs"]
mod common;

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::report::{comparison_headers, comparison_row, Table};
use jasda::sim::SimEngine;

fn main() {
    let cfg = common::contended_cfg(21, 80);
    let jobs = common::workload(&cfg);
    println!(
        "Table 1 (measured): {} jobs on {} '{}' GPU(s), seed {}",
        jobs.len(),
        cfg.cluster.num_gpus,
        cfg.cluster.layout,
        cfg.seed
    );

    let mut table = Table::new("Table 1 — scheduling models, measured", &comparison_headers());
    for name in ALL_SCHEDULERS {
        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let out = SimEngine::new(cfg.clone(), sched).run(jobs.clone());
        assert_eq!(out.metrics.unfinished, 0, "{name} left jobs unfinished");
        table.push_row(comparison_row(&out.metrics));
    }
    println!("\n{}", table.to_markdown());

    println!("Correspondence to the paper's qualitative rows:");
    println!("  granularity    -> subjobs/job: monolithic ~1, atomized >1, JASDA highest");
    println!("  participation  -> JASDA is the only scheduler whose variants carry job scores");
    println!("  adaptivity     -> starvation/jain: JASDA lowest starvation on this trace");
}
