//! Headline comparison (§1/§6(a)) — JASDA vs every baseline on mixed
//! workloads across load regimes and cluster shapes: the experiment the
//! paper's promised follow-up study would lead with.

#[path = "common/mod.rs"]
mod common;

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::report::{comparison_headers, comparison_row, Table};
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    println!("Headline: scheduler comparison across regimes\n");
    let scenarios: [(&str, u32, &str, f64, usize); 3] = [
        ("1 GPU heterogeneous, light", 1, "heterogeneous", 0.12, 60),
        ("1 GPU heterogeneous, contended", 1, "heterogeneous", 0.35, 60),
        ("2 GPUs 7x1g + balanced, contended", 2, "balanced", 0.6, 100),
    ];
    for (label, gpus, layout, rate, n) in scenarios {
        let mut cfg = common::contended_cfg(71, n);
        cfg.cluster.num_gpus = gpus;
        cfg.cluster.layout = layout.into();
        cfg.workload.arrival_rate_per_sec = rate;
        let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);

        let mut table = Table::new(format!("headline — {label}"), &comparison_headers());
        let mut jasda_starv = 0;
        let mut best_other_starv = u64::MAX;
        for name in ALL_SCHEDULERS {
            let sched = by_name(name, &cfg.jasda).expect("known scheduler");
            let m = SimEngine::new(cfg.clone(), sched).run(jobs.clone()).metrics;
            if name == "jasda" {
                jasda_starv = m.max_starvation();
            } else if m.unfinished == 0 {
                best_other_starv = best_other_starv.min(m.max_starvation());
            }
            table.push_row(comparison_row(&m));
        }
        // Extension row: duration-weighted clearing (EXPERIMENTS.md F6).
        {
            let mut jcfg = cfg.jasda.clone();
            jcfg.duration_weighted_clearing = true;
            let m = SimEngine::new(
                cfg.clone(),
                Box::new(jasda::jasda::JasdaScheduler::new(jcfg)),
            )
            .run(jobs.clone())
            .metrics;
            let mut row = comparison_row(&m);
            row[0] = "jasda(dw)".into();
            table.push_row(row);
        }
        println!("{}", table.to_markdown());
        println!(
            "starvation: jasda {} vs best baseline {} -> {}\n",
            jasda_starv,
            best_other_starv,
            if jasda_starv <= best_other_starv {
                "JASDA wins (paper's fairness claim holds)"
            } else {
                "baseline wins on this trace"
            }
        );
    }
}
