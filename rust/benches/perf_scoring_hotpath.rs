//! Hot-path performance: the batched scoring pipeline (native vs PJRT)
//! and the end-to-end iteration cost (EXPERIMENTS.md §Perf).

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use jasda::jasda::JasdaScheduler;
use jasda::runtime::{PjrtScorer, T_BINS};
use jasda::sim::{Rng, SimEngine};
use jasda::types::Interval;
use jasda::util::bench::{header, run_case};

fn batch(m: usize, seed: u64) -> ScoreBatch {
    let mut rng = Rng::new(seed);
    let mut b = ScoreBatch::with_bins(T_BINS);
    b.capacity = 20.0;
    b.theta = 0.05;
    b.lambda = 0.5;
    b.alpha = [0.45, 0.25, 0.15, 0.15];
    b.beta = [0.45, 0.2, 0.15, 0.2];
    for _ in 0..m {
        let base = rng.uniform_range(2.0, 16.0);
        let mu: Vec<f64> = (0..T_BINS).map(|_| base + rng.uniform_range(-0.5, 0.5)).collect();
        let sigma: Vec<f64> = (0..T_BINS).map(|_| rng.uniform_range(0.05, 1.0)).collect();
        b.push(
            &mu,
            &sigma,
            [rng.uniform(); 4],
            [rng.uniform(), rng.uniform(), rng.uniform()],
            0.7,
            0.5,
        );
    }
    b
}

fn main() {
    header("L3 scoring backends (per batch, T=64 bins)");
    let mut native = NativeScorer;
    for &m in &[64usize, 256, 1024, 4096] {
        let b = batch(m, m as u64);
        let meas = run_case(&format!("native scorer M={m}"), 10, 5, || {
            native.score(std::hint::black_box(&b)).unwrap().score[0]
        });
        println!(
            "{:<48}   -> {:.0} variants/ms",
            "",
            m as f64 / (meas.ns_per_iter() / 1e6)
        );
    }

    header("L3 scoring, per-row capacity (K-window union batches)");
    for &m in &[256usize, 4096] {
        let mut b = batch(m, m as u64);
        // Rows grouped by window, 4 windows with distinct capacities.
        b.row_capacity = (0..m)
            .map(|i| [20.0f32, 10.0, 10.0, 5.0][(i * 4) / m.max(1)])
            .collect();
        let meas = run_case(&format!("native scorer M={m} (4 windows)"), 10, 5, || {
            native.score(std::hint::black_box(&b)).unwrap().score[0]
        });
        println!(
            "{:<48}   -> {:.0} variants/ms",
            "",
            m as f64 / (meas.ns_per_iter() / 1e6)
        );
    }

    let artifact = jasda::runtime::artifacts_dir().join("scorer.hlo.txt");
    match PjrtScorer::load(&artifact) {
        Ok(mut pjrt) => {
            for &m in &[256usize, 1024, 4096] {
                let b = batch(m, m as u64);
                let meas = run_case(&format!("pjrt scorer   M={m}"), 5, 10, || {
                    pjrt.score(std::hint::black_box(&b)).unwrap().score[0]
                });
                println!(
                    "{:<48}   -> {:.0} variants/ms",
                    "",
                    m as f64 / (meas.ns_per_iter() / 1e6)
                );
            }
        }
        Err(e) => println!("(pjrt rows skipped: {e})"),
    }

    header("WIS clearing throughput");
    for &m in &[1024usize, 16384] {
        let mut rng = Rng::new(m as u64);
        let items: Vec<WisItem> = (0..m)
            .map(|_| {
                let s = rng.below(100_000);
                WisItem {
                    interval: Interval::new(s, s + 1 + rng.below(500)),
                    score: rng.uniform(),
                }
            })
            .collect();
        let meas = run_case(&format!("clearing M={m}"), 10, 5, || {
            select_best_compatible(std::hint::black_box(&items)).total_score
        });
        println!(
            "{:<48}   -> {:.2}M variants/s",
            "",
            m as f64 / (meas.ns_per_iter() / 1e9) / 1e6
        );
    }

    header("end-to-end scheduler iteration (full simulation amortized)");
    let cfg = common::contended_cfg(81, 50);
    let jobs = common::workload(&cfg);
    let meas = run_case("full 50-job simulation", 5, 50, || {
        SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs.clone())
            .metrics
            .makespan
    });
    let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
        .run(jobs.clone())
        .metrics;
    println!(
        "  iterations {}  sched {:.1} ns/iter  sim wall {:.1} ms",
        m.iterations,
        m.sched_ns_per_iteration(),
        meas.ns_per_iter() / 1e6,
    );

    header("K-window announcement sweep (full simulation per K)");
    for (label, k, per_slice) in
        [("K=1", 1usize, false), ("K=2", 2, false), ("K=4", 4, false), ("K=slices", 1, true)]
    {
        let mut kcfg = common::contended_cfg(81, 50);
        kcfg.jasda.announce_k = k;
        kcfg.jasda.announce_per_slice = per_slice;
        let kjobs = common::workload(&kcfg);
        let meas = run_case(&format!("50-job simulation {label}"), 5, 50, || {
            SimEngine::new(kcfg.clone(), Box::new(JasdaScheduler::new(kcfg.jasda.clone())))
                .run(kjobs.clone())
                .metrics
                .makespan
        });
        let m = SimEngine::new(kcfg.clone(), Box::new(JasdaScheduler::new(kcfg.jasda.clone())))
            .run(kjobs.clone())
            .metrics;
        println!(
            "{:<48}   -> {:.3} commits/iter  makespan {}  sched {:.0} ns/iter  wall {:.1} ms",
            "",
            m.commits_per_iteration(),
            m.makespan,
            m.sched_ns_per_iteration(),
            meas.ns_per_iter() / 1e6,
        );
    }
}
