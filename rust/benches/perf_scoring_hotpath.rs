//! Hot-path performance: the batched scoring pipeline (native vs PJRT),
//! the end-to-end iteration cost (EXPERIMENTS.md §Perf), and — since
//! ISSUE 2 — before/after sweeps of the incremental gap index and the
//! parallel clearing pipeline over slice count and reservation density,
//! emitted as machine-readable `BENCH_iteration.json` (override the path
//! with `BENCH_OUT`; set `BENCH_SMOKE=1` for a fast CI smoke run).

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use jasda::jasda::JasdaScheduler;
use jasda::mig::{Cluster, PartitionLayout, Reservation};
use jasda::runtime::{PjrtScorer, T_BINS};
use jasda::sim::{Rng, SimEngine};
use jasda::types::Interval;
use jasda::util::bench::{header, run_case};
use jasda::util::Json;

/// A cluster whose every slice carries `density` short reservations —
/// the dense-timeline regime where per-iteration gap recomputation used
/// to dominate.
fn dense_cluster(gpus: u32, density: usize) -> Cluster {
    let mut c = Cluster::new(gpus, &PartitionLayout::seven_small());
    for s in 0..c.num_slices() as u32 {
        for k in 0..density {
            let start = 100 * k as u64 + (s as u64 * 13) % 40;
            let iv = Interval::new(start, start + 60);
            let _ = c
                .slice_mut(s)
                .timeline
                .reserve(Reservation { job: s, subjob_seq: k as u32, interval: iv });
        }
    }
    c
}

fn batch(m: usize, seed: u64) -> ScoreBatch {
    let mut rng = Rng::new(seed);
    let mut b = ScoreBatch::with_bins(T_BINS);
    b.capacity = 20.0;
    b.theta = 0.05;
    b.lambda = 0.5;
    b.alpha = [0.45, 0.25, 0.15, 0.15];
    b.beta = [0.45, 0.2, 0.15, 0.2];
    for _ in 0..m {
        let base = rng.uniform_range(2.0, 16.0);
        let mu: Vec<f64> = (0..T_BINS).map(|_| base + rng.uniform_range(-0.5, 0.5)).collect();
        let sigma: Vec<f64> = (0..T_BINS).map(|_| rng.uniform_range(0.05, 1.0)).collect();
        b.push(
            &mu,
            &sigma,
            [rng.uniform(); 4],
            [rng.uniform(), rng.uniform(), rng.uniform()],
            0.7,
            0.5,
        );
    }
    b
}

fn main() {
    header("L3 scoring backends (per batch, T=64 bins)");
    let mut native = NativeScorer;
    for &m in &[64usize, 256, 1024, 4096] {
        let b = batch(m, m as u64);
        let meas = run_case(&format!("native scorer M={m}"), 10, 5, || {
            native.score(std::hint::black_box(&b)).unwrap().score[0]
        });
        println!(
            "{:<48}   -> {:.0} variants/ms",
            "",
            m as f64 / (meas.ns_per_iter() / 1e6)
        );
    }

    header("L3 scoring, per-row capacity (K-window union batches)");
    for &m in &[256usize, 4096] {
        let mut b = batch(m, m as u64);
        // Rows grouped by window, 4 windows with distinct capacities.
        b.row_capacity = (0..m)
            .map(|i| [20.0f32, 10.0, 10.0, 5.0][(i * 4) / m.max(1)])
            .collect();
        let meas = run_case(&format!("native scorer M={m} (4 windows)"), 10, 5, || {
            native.score(std::hint::black_box(&b)).unwrap().score[0]
        });
        println!(
            "{:<48}   -> {:.0} variants/ms",
            "",
            m as f64 / (meas.ns_per_iter() / 1e6)
        );
    }

    let artifact = jasda::runtime::artifacts_dir().join("scorer.hlo.txt");
    match PjrtScorer::load(&artifact) {
        Ok(mut pjrt) => {
            for &m in &[256usize, 1024, 4096] {
                let b = batch(m, m as u64);
                let meas = run_case(&format!("pjrt scorer   M={m}"), 5, 10, || {
                    pjrt.score(std::hint::black_box(&b)).unwrap().score[0]
                });
                println!(
                    "{:<48}   -> {:.0} variants/ms",
                    "",
                    m as f64 / (meas.ns_per_iter() / 1e6)
                );
            }
        }
        Err(e) => println!("(pjrt rows skipped: {e})"),
    }

    header("WIS clearing throughput");
    for &m in &[1024usize, 16384] {
        let mut rng = Rng::new(m as u64);
        let items: Vec<WisItem> = (0..m)
            .map(|_| {
                let s = rng.below(100_000);
                WisItem {
                    interval: Interval::new(s, s + 1 + rng.below(500)),
                    score: rng.uniform(),
                }
            })
            .collect();
        let meas = run_case(&format!("clearing M={m}"), 10, 5, || {
            select_best_compatible(std::hint::black_box(&items)).total_score
        });
        println!(
            "{:<48}   -> {:.2}M variants/s",
            "",
            m as f64 / (meas.ns_per_iter() / 1e9) / 1e6
        );
    }

    header("end-to-end scheduler iteration (full simulation amortized)");
    let cfg = common::contended_cfg(81, 50);
    let jobs = common::workload(&cfg);
    let meas = run_case("full 50-job simulation", 5, 50, || {
        SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
            .run(jobs.clone())
            .metrics
            .makespan
    });
    let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
        .run(jobs.clone())
        .metrics;
    println!(
        "  iterations {}  sched {:.1} ns/iter  sim wall {:.1} ms",
        m.iterations,
        m.sched_ns_per_iteration(),
        meas.ns_per_iter() / 1e6,
    );

    header("K-window announcement sweep (full simulation per K)");
    for (label, k, per_slice) in
        [("K=1", 1usize, false), ("K=2", 2, false), ("K=4", 4, false), ("K=slices", 1, true)]
    {
        let mut kcfg = common::contended_cfg(81, 50);
        kcfg.jasda.announce_k = k;
        kcfg.jasda.announce_per_slice = per_slice;
        let kjobs = common::workload(&kcfg);
        let meas = run_case(&format!("50-job simulation {label}"), 5, 50, || {
            SimEngine::new(kcfg.clone(), Box::new(JasdaScheduler::new(kcfg.jasda.clone())))
                .run(kjobs.clone())
                .metrics
                .makespan
        });
        let m = SimEngine::new(kcfg.clone(), Box::new(JasdaScheduler::new(kcfg.jasda.clone())))
            .run(kjobs.clone())
            .metrics;
        println!(
            "{:<48}   -> {:.3} commits/iter  makespan {}  sched {:.0} ns/iter  wall {:.1} ms",
            "",
            m.commits_per_iteration(),
            m.makespan,
            m.sched_ns_per_iteration(),
            meas.ns_per_iter() / 1e6,
        );
    }

    // ------------------------------------------------------------------
    // ISSUE 2: iteration-latency sweeps + machine-readable baseline.
    // ------------------------------------------------------------------
    let smoke = std::env::var("BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let (samples, sample_ms) = if smoke { (3, 2) } else { (10, 20) };

    header("candidate-window enumeration: full scan vs incremental gap index");
    let mut enum_rows: Vec<Json> = Vec::new();
    let density_sweep: &[(u32, usize)] =
        if smoke { &[(1, 50), (4, 100)] } else { &[(1, 50), (2, 100), (4, 200), (8, 200)] };
    for &(gpus, density) in density_sweep {
        let c = dense_cluster(gpus, density);
        let slices = c.num_slices();
        let horizon = 100 * density as u64 + 10_000;
        let scan = run_case(
            &format!("scan  {slices} slices x {density} resv"),
            samples,
            sample_ms,
            || {
                let mut n = 0usize;
                for s in c.slices() {
                    n += s.timeline.idle_gaps_scan(0, horizon, 25).len();
                }
                n
            },
        );
        let mut buf = Vec::new();
        let index = run_case(
            &format!("index {slices} slices x {density} resv"),
            samples,
            sample_ms,
            || {
                c.collect_windows(0, horizon, 25, &mut buf);
                buf.len()
            },
        );
        let speedup = scan.ns_per_iter() / index.ns_per_iter().max(1.0);
        println!("{:<48}   -> {speedup:.2}x over full scan", "");
        enum_rows.push(Json::obj(vec![
            ("slices", slices.into()),
            ("reservations_per_slice", density.into()),
            ("scan_ns", scan.ns_per_iter().into()),
            ("index_ns", index.ns_per_iter().into()),
            ("speedup", speedup.into()),
        ]));
    }

    header("end-to-end iteration latency: serial vs parallel pipeline");
    let mut iter_rows: Vec<Json> = Vec::new();
    // `heterogeneous` = 3 slices/GPU; every generated job fits its 20 GiB
    // slice, so runs complete. 6 GPUs = 18 slices covers the "16+
    // slices, dense timelines" acceptance point.
    let gpu_sweep: &[u32] = if smoke { &[1, 2] } else { &[1, 2, 6] };
    for &gpus in gpu_sweep {
        for (mode, threads) in [("serial", 1usize), ("parallel", 0)] {
            let mut cfg = common::contended_cfg(81, if smoke { 20 } else { 30 * gpus as usize });
            cfg.cluster.num_gpus = gpus;
            cfg.jasda.announce_per_slice = true;
            cfg.jasda.parallel = threads;
            // Bound pathological runs so the bench always terminates.
            cfg.engine.max_time = 20_000_000;
            let jobs = common::workload(&cfg);
            let m = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
                .run(jobs.clone())
                .metrics;
            let slices = 3 * gpus as usize;
            println!(
                "{mode:<9} {slices:>3} slices: sched {:>10.0} ns/iter  max {:>10} ns  makespan {}  commits/iter {:.3}",
                m.sched_ns_per_iteration(),
                m.max_sched_iter_ns,
                m.makespan,
                m.commits_per_iteration(),
            );
            iter_rows.push(Json::obj(vec![
                ("slices", slices.into()),
                ("jobs", cfg.workload.num_jobs.into()),
                ("mode", mode.into()),
                ("announce", "per_slice".into()),
                ("sched_ns_per_iter", m.sched_ns_per_iteration().into()),
                ("max_sched_iter_ns", m.max_sched_iter_ns.into()),
                ("makespan", m.makespan.into()),
                ("commits_per_iter", m.commits_per_iteration().into()),
                ("iterations", m.iterations.into()),
                ("unfinished", m.unfinished.into()),
            ]));
        }
    }

    // ------------------------------------------------------------------
    // ISSUE 3: coordinator-path round latency — single-window vs
    // K-window rounds, serial vs persistent-pool fan-out, and the
    // threaded protocol vs the in-process reference oracle.
    // ------------------------------------------------------------------
    header("coordinator protocol round latency (leader decision path)");
    let mut proto_rows: Vec<Json> = Vec::new();
    for (label, k, per_slice) in
        [("K=1", 1usize, false), ("K=2", 2, false), ("K=slices", 1, true)]
    {
        for (mode, threads) in [("serial", 1usize), ("pool", 0)] {
            let mut cfg = common::contended_cfg(81, if smoke { 10 } else { 30 });
            cfg.jasda.announce_k = k;
            cfg.jasda.announce_per_slice = per_slice;
            cfg.jasda.parallel = threads;
            let jobs = common::workload(&cfg);
            let proto =
                jasda::coordinator::run_protocol(cfg.clone(), jobs.clone(), 3_000_000);
            let reference = jasda::coordinator::run_reference(cfg, jobs, 3_000_000);
            println!(
                "{label:<9} {mode:<7}: proto {:>9.0} ns/round (max {:>9} ns)  \
                 reference {:>9.0} ns/round  windows/round {:.2}  wall {:.1?} ",
                proto.decision_ns_per_round(),
                proto.max_round_decision_ns,
                reference.decision_ns_per_round(),
                proto.windows_announced as f64 / proto.announcements.max(1) as f64,
                proto.wall,
            );
            proto_rows.push(Json::obj(vec![
                ("announce", label.into()),
                ("mode", mode.into()),
                ("rounds", proto.rounds.into()),
                ("windows_announced", proto.windows_announced.into()),
                ("proto_decision_ns_per_round", proto.decision_ns_per_round().into()),
                ("proto_max_round_decision_ns", proto.max_round_decision_ns.into()),
                ("reference_decision_ns_per_round", reference.decision_ns_per_round().into()),
                ("proto_completed", proto.completed_jobs.into()),
                ("proto_wall_ms", (proto.wall.as_nanos() as f64 / 1e6).into()),
            ]));
        }
    }

    // ------------------------------------------------------------------
    // ISSUE 6 + 9: shards x transport sweep — what the wire codec costs
    // per round, what real sockets add on top of it (tcp/unix rows ride
    // in via TransportKind::ALL), and what N-leader clearing buys (or
    // costs, once the reconciler's sequential pass is counted) on a
    // contended workload.
    // ------------------------------------------------------------------
    header("sharded coordinator round latency (shards x transport)");
    use jasda::config::TransportKind;
    for &shards in if smoke { &[1usize, 2][..] } else { &[1usize, 2, 4][..] } {
        for transport in TransportKind::ALL {
            let mut cfg = common::contended_cfg(81, if smoke { 10 } else { 30 });
            cfg.jasda.announce_per_slice = true;
            cfg.jasda.shards = shards;
            cfg.jasda.transport = transport;
            let jobs = common::workload(&cfg);
            let proto = jasda::coordinator::run_protocol(cfg, jobs, 3_000_000);
            println!(
                "shards={shards} {:<9}: proto {:>9.0} ns/round (max {:>9} ns)  \
                 cross-shard {:>5}  dropped {:>3}  wall {:.1?}",
                transport.name(),
                proto.decision_ns_per_round(),
                proto.max_round_decision_ns,
                proto.cross_shard_conflicts,
                proto.sends_dropped,
                proto.wall,
            );
            proto_rows.push(Json::obj(vec![
                ("announce", "K=slices".into()),
                ("mode", "pool".into()),
                ("shards", shards.into()),
                ("transport", transport.name().into()),
                ("rounds", proto.rounds.into()),
                ("windows_announced", proto.windows_announced.into()),
                ("proto_decision_ns_per_round", proto.decision_ns_per_round().into()),
                ("proto_max_round_decision_ns", proto.max_round_decision_ns.into()),
                ("cross_shard_conflicts", proto.cross_shard_conflicts.into()),
                ("sends_dropped", proto.sends_dropped.into()),
                ("proto_completed", proto.completed_jobs.into()),
                ("proto_wall_ms", (proto.wall.as_nanos() as f64 / 1e6).into()),
            ]));
        }
    }

    // ------------------------------------------------------------------
    // ISSUE 7: what fault tolerance costs. Arming the round deadline
    // without faults measures the pure overhead of the deadline-aware
    // receive path (it should be noise — the deadline arm is never
    // taken in a healthy run); the fault-storm row shows round latency
    // under injected adversity, where timed-out rounds wait out the
    // configured deadline and quarantine/Resync traffic joins the
    // rounds.
    // ------------------------------------------------------------------
    header("fault-tolerant round latency (deadline armed / fault storm)");
    for (label, timeout_ms, crash) in
        [("no deadline", 0u64, 0.0f64), ("deadline armed", 1_000, 0.0), ("fault storm", 50, 0.5)]
    {
        let mut cfg = common::contended_cfg(81, if smoke { 10 } else { 30 });
        cfg.jasda.announce_per_slice = true;
        cfg.jasda.round_timeout_ms = timeout_ms;
        if crash > 0.0 {
            cfg.jasda.faults.seed = 81;
            cfg.jasda.faults.crash = crash;
            cfg.jasda.faults.delay = 0.3;
            cfg.jasda.faults.horizon_rounds = 32;
            cfg.jasda.faults.crash_rounds = 8;
        }
        cfg.validate().expect("bench fault config");
        let jobs = common::workload(&cfg);
        let proto = jasda::coordinator::run_protocol(cfg, jobs, 3_000_000);
        println!(
            "{label:<15}: proto {:>9.0} ns/round (max {:>9} ns)  timed-out {:>3}  \
             quarantined {:>2}  readmitted {:>2}  wall {:.1?}",
            proto.decision_ns_per_round(),
            proto.max_round_decision_ns,
            proto.rounds_timed_out,
            proto.agents_quarantined,
            proto.readmissions,
            proto.wall,
        );
        proto_rows.push(Json::obj(vec![
            ("announce", "K=slices".into()),
            ("mode", label.into()),
            ("round_timeout_ms", timeout_ms.into()),
            ("fault_crash", crash.into()),
            ("rounds", proto.rounds.into()),
            ("rounds_timed_out", proto.rounds_timed_out.into()),
            ("stragglers", proto.stragglers.into()),
            ("agents_quarantined", proto.agents_quarantined.into()),
            ("readmissions", proto.readmissions.into()),
            ("proto_decision_ns_per_round", proto.decision_ns_per_round().into()),
            ("proto_max_round_decision_ns", proto.max_round_decision_ns.into()),
            ("proto_completed", proto.completed_jobs.into()),
            ("proto_wall_ms", (proto.wall.as_nanos() as f64 / 1e6).into()),
        ]));
    }

    // ------------------------------------------------------------------
    // ISSUE 8: exact global clearing — branch-and-bound node counts and
    // solve latency as the `jasda.clearing_budget_ms` budget tightens.
    // Budget 0 is the instant-fallback floor (greedy incumbent, zero
    // search); larger budgets let the solver run until exhaustion or
    // proof of optimality.
    // ------------------------------------------------------------------
    header("exact clearing solve latency vs budget (branch-and-bound)");
    use jasda::config::ClearingMode;
    for &budget_ms in if smoke { &[0u64, 5][..] } else { &[0u64, 1, 5, 20][..] } {
        let mut cfg = common::contended_cfg(81, if smoke { 10 } else { 30 });
        cfg.jasda.announce_per_slice = true;
        cfg.jasda.clearing = ClearingMode::Exact;
        cfg.jasda.clearing_budget_ms = budget_ms;
        let jobs = common::workload(&cfg);
        let proto = jasda::coordinator::run_protocol(cfg, jobs, 3_000_000);
        let exact_ns_per_round =
            proto.exact_ns as f64 / proto.exact_rounds.max(1) as f64;
        println!(
            "budget {budget_ms:>2} ms: proto {:>9.0} ns/round  exact rounds {:>4}  \
             nodes {:>6}  improved {:>3}  exhausted {:>4}  solve {:>9.0} ns/round",
            proto.decision_ns_per_round(),
            proto.exact_rounds,
            proto.exact_nodes,
            proto.exact_improved,
            proto.exact_budget_exhausted,
            exact_ns_per_round,
        );
        proto_rows.push(Json::obj(vec![
            ("announce", "K=slices".into()),
            ("mode", "exact".into()),
            ("clearing_budget_ms", budget_ms.into()),
            ("rounds", proto.rounds.into()),
            ("exact_rounds", proto.exact_rounds.into()),
            ("exact_nodes", proto.exact_nodes.into()),
            ("exact_improved", proto.exact_improved.into()),
            ("exact_budget_exhausted", proto.exact_budget_exhausted.into()),
            ("exact_solve_ns_per_round", exact_ns_per_round.into()),
            ("proto_decision_ns_per_round", proto.decision_ns_per_round().into()),
            ("proto_max_round_decision_ns", proto.max_round_decision_ns.into()),
            ("proto_completed", proto.completed_jobs.into()),
            ("proto_wall_ms", (proto.wall.as_nanos() as f64 / 1e6).into()),
        ]));
    }

    let out = Json::obj(vec![
        ("schema", "jasda.bench_iteration.v1".into()),
        ("smoke", smoke.into()),
        ("enumeration", Json::Arr(enum_rows)),
        ("iteration", Json::Arr(iter_rows)),
        ("protocol", Json::Arr(proto_rows)),
    ]);
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_iteration.json".into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
