//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! in-tree crate provides the exact subset of anyhow's API the framework
//! uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and blanket conversion from any standard error so
//! `?` works on `io::Error` & friends. Semantics match upstream for this
//! subset; swap in the real crate by deleting `vendor/anyhow` and adding
//! `anyhow = "1"` once a registry is reachable.

use std::fmt;

/// A type-erased error, displayable and convertible from any
/// `std::error::Error`.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Message-only error payload backing [`Error::msg`].
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Build an error from any standard error value.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { inner: Box::new(error) }
    }

    /// The underlying cause chain's root, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.inner.source()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Like upstream anyhow: Debug prints the display message (plus
        // the source chain when present) so `main() -> Result<()>` output
        // stays readable.
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes this blanket conversion coherent (same trick as
// upstream anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn debug_includes_message() {
        let e = anyhow!("top level");
        assert!(format!("{e:?}").contains("top level"));
    }
}
